"""Offered-load serving benchmark: TTFT/latency percentiles vs QPS x tier.

The paper's headline claim is *near-DRAM end-to-end performance under real
serving load*. This bench reproduces it as a measured curve on the virtual
clock (serving/clock.py): a Poisson arrival process at an offered QPS is
served from each pool tier at the emulated production operating point, and
per-request TTFT / end-to-end latency percentiles are computed from the
virtual timestamps — fully deterministic (no host-timing noise).

Outputs
-------
  * ``load_curves.csv`` + stdout rows — one row per (tier, qps):
    p50/p95/p99 virtual TTFT and latency, virtual token throughput,
    stall and link-wait totals.
  * ``BENCH_load.json`` — the full sweep plus the shared-cache split
    experiment and the pass/fail checks (the CI ``load-smoke`` job
    uploads this artifact and fails on a violated check):
      - ``cxl_tracks_dram``: at the lowest offered load, CXL p50 TTFT is
        within ``TOL_CXL`` of DRAM-only (the paper's Table 2/3 story);
      - ``rdma_diverges``: RDMA's absolute p50 TTFT gap over DRAM grows
        with offered load (queueing compounds the per-wave stall) and its
        ratio exceeds CXL's at the highest point;
      - ``shared_cache_split``: at the switch-saturation operating point
        two replicas on ONE pre-warmed shared hot-row cache are strictly
        slower than two pre-warmed private caches (bandwidth-split
        contention) — identical traffic, 100% hit rates in both configs,
        the only difference is the clock link the hits queue on.
"""
from __future__ import annotations

import dataclasses
import json
import sys

import numpy as np

from repro.configs.base import StoreConfig
from repro.launch.train import reduced_config
from repro.serving import Router, Workload, serve

from .common import OUT_DIR, emit, write_csv

EMULATED_STEP_S = 2e-4       # production decode cadence (Table 2/3 point)
SATURATION_STEP_S = 2e-6     # switch-saturation point: windows ~ tier lat
TOL_CXL = 1.25               # CXL p50 TTFT within 25% of DRAM at low load


def _tiny_cfg(cache_rows: int = 0):
    cfg = reduced_config("deepseek-7b")
    e = dataclasses.replace(cfg.engram, layers=(1,),
                            store=StoreConfig(cache_rows=cache_rows))
    return dataclasses.replace(cfg, n_layers=3, layer_types=("attn",) * 3,
                               attn_kinds=("global",) * 3,
                               ffn_types=("dense",) * 3, engram=e)


def _pct(xs, q) -> float:
    return float(np.percentile(np.asarray(xs), q)) if len(xs) else 0.0


def _drive(cfg, *, pool, qps, requests, max_new, replicas=1,
           shared_cache=True, step_s=EMULATED_STEP_S, seed=0):
    w = Workload(requests=requests, max_new=max_new, arrival="poisson",
                 qps=qps, zipf_alpha=1.4, prompt_pool=max(2, requests // 4),
                 seed=seed)
    res = serve(cfg, w, pool=pool, replicas=replicas,
                policy="least_loaded" if replicas > 1 else "round_robin",
                shared_cache=shared_cache, max_batch=4, max_len=64,
                prompt_bucket=8, emulate_step_s=step_s)
    ttft = res.ttft_v()
    lat = res.latency_v()
    st = res.stats
    wait_s = 0.0
    ss = res.store_stats()
    if isinstance(ss, dict):
        wait_s = sum(s.wait_s for s in ss.values())
    elif ss is not None:
        wait_s = ss.wait_s
    return {
        "pool": pool or "DRAM-local", "qps": qps, "replicas": replicas,
        "shared_cache": bool(shared_cache and replicas > 1),
        "requests": len(ttft),
        "ttft_p50_us": _pct(ttft, 50) * 1e6,
        "ttft_p95_us": _pct(ttft, 95) * 1e6,
        "ttft_p99_us": _pct(ttft, 99) * 1e6,
        "lat_p50_us": _pct(lat, 50) * 1e6,
        "lat_p99_us": _pct(lat, 99) * 1e6,
        "v_time_s": st.v_time_s,
        "tokens_per_vs": st.generated_tokens / max(st.v_time_s, 1e-12),
        "stall_ms": st.stall_s * 1e3,
        "link_wait_us": wait_s * 1e6,
        # prefill accounting (bench_prefill.py optimizes these; here they
        # contextualize the TTFT curves — pad compute and admission waves
        # are part of what the offered load queues behind)
        "pad_row_fraction": st.pad_row_fraction,
        "prefill_waves_per_request": st.prefill_waves_per_request,
        "prefix_hit_rate": st.prefix_hit_rate,
    }


def _split_drive(cfg, *, shared: bool, requests: int, max_new: int) -> dict:
    """Shared-vs-private cache split at the saturation point: warm a
    2-replica fleet on a fixed request set, then re-serve the identical
    set and measure the warm pass alone (100% hit rate either way)."""
    router = Router(cfg, replicas=2, pool="DRAM", policy="round_robin",
                    shared_cache=shared, max_batch=4, max_len=64,
                    prompt_bucket=8, emulate_step_s=SATURATION_STEP_S)
    prompts = [[3 + r % 5, 17, 42 + r % 7, 9] for r in range(requests)]
    for p in prompts:                       # warm pass: identical traffic
        router.submit(list(p), max_new)
    router.drain()
    for rt in router.replicas:
        rt.engine.reset_stats()
        if rt.engine.store is not None:
            rt.engine.store.reset_stats()
    t0 = router.clock.now_s
    handles = [router.submit(list(p), max_new) for p in prompts]
    router.drain()
    ttft = [h.request.first_token_v - h.request.submitted_v
            for h in handles]
    ss = router.store_stats()
    hits = sum(s.hits for s in ss.values())
    misses = sum(s.misses for s in ss.values())
    return {
        "shared": shared,
        "ttft_p50_us": _pct(ttft, 50) * 1e6,
        "ttft_p99_us": _pct(ttft, 99) * 1e6,
        "drain_vs": router.clock.now_s - t0,
        "hit_rate": hits / max(hits + misses, 1),
        "link_wait_us": sum(s.wait_s for s in ss.values()) * 1e6,
        "stall_us": sum(s.stall_s for s in ss.values()) * 1e6,
    }


def run(fast: bool = False) -> dict:
    cfg = _tiny_cfg()
    requests = 10 if fast else 32
    max_new = 5 if fast else 10
    qps_grid = (500.0, 4000.0, 16000.0) if fast \
        else (250.0, 1000.0, 4000.0, 16000.0)

    rows = []
    by = {}
    for pool in ("DRAM", "CXL", "RDMA"):
        for qps in qps_grid:
            r = _drive(cfg, pool=pool, qps=qps, requests=requests,
                       max_new=max_new)
            rows.append(r)
            by[(pool, qps)] = r
            emit(f"load/{pool}/qps{int(qps)}", r["ttft_p50_us"],
                 f"ttft_p99={r['ttft_p99_us']:.1f}us "
                 f"lat_p50={r['lat_p50_us']:.1f}us "
                 f"tok/vs={r['tokens_per_vs']:.0f} "
                 f"stall={r['stall_ms']:.3f}ms")
    write_csv("load_curves",
              list(rows[0].keys()), [list(r.values()) for r in rows])

    lo, hi = qps_grid[0], qps_grid[-1]
    cxl_ratio_lo = by[("CXL", lo)]["ttft_p50_us"] \
        / max(by[("DRAM", lo)]["ttft_p50_us"], 1e-9)
    rdma_ratio_lo = by[("RDMA", lo)]["ttft_p50_us"] \
        / max(by[("DRAM", lo)]["ttft_p50_us"], 1e-9)
    cxl_ratio_hi = by[("CXL", hi)]["ttft_p50_us"] \
        / max(by[("DRAM", hi)]["ttft_p50_us"], 1e-9)
    rdma_ratio_hi = by[("RDMA", hi)]["ttft_p50_us"] \
        / max(by[("DRAM", hi)]["ttft_p50_us"], 1e-9)

    # shared-cache bandwidth split: two replicas, one hot-row cache vs two
    # private ones, at the switch-saturation operating point where the
    # prefetch window is comparable to the cache-hit latency. Both fleets
    # are pre-warmed on the identical request set, so the measured pass
    # runs at 100% hit rate in BOTH configs — cold-miss asymmetry (the
    # shared cache warms once, private ones twice: the PR 3 result) is
    # excluded, and the only delta is the link the hits queue on.
    cache_cfg = _tiny_cfg(cache_rows=200_000)
    shared = _split_drive(cache_cfg, shared=True, requests=requests,
                          max_new=max_new)
    private = _split_drive(cache_cfg, shared=False, requests=requests,
                           max_new=max_new)
    emit("load/shared_cache_split",
         shared["ttft_p99_us"] - private["ttft_p99_us"],
         f"shared_p99={shared['ttft_p99_us']:.2f}us "
         f"private_p99={private['ttft_p99_us']:.2f}us "
         f"shared_drain={shared['drain_vs']*1e6:.1f}us "
         f"private_drain={private['drain_vs']*1e6:.1f}us "
         f"shared_wait={shared['link_wait_us']:.3f}us "
         f"hit_rates={shared['hit_rate']:.3f}/{private['hit_rate']:.3f}")

    rdma_gap_lo = by[("RDMA", lo)]["ttft_p50_us"] \
        - by[("DRAM", lo)]["ttft_p50_us"]
    rdma_gap_hi = by[("RDMA", hi)]["ttft_p50_us"] \
        - by[("DRAM", hi)]["ttft_p50_us"]
    checks = {
        # paper claim: CXL tracks DRAM at low utilization
        "cxl_tracks_dram": bool(cxl_ratio_lo <= TOL_CXL),
        # RDMA's absolute TTFT penalty must compound with offered load
        # (queueing amplifies the per-wave stall) and beat CXL's ratio
        "rdma_diverges": bool(rdma_gap_hi > rdma_gap_lo
                              and rdma_ratio_hi > cxl_ratio_hi),
        # bandwidth split: one cache serving two replicas is strictly
        # slower than two private caches under saturation, at equal
        # (unit) hit rates — visible in the TTFT tail (the first wave is
        # contention-free by construction, so p50 cannot move), the fleet
        # drain time, and the measured link queueing
        "shared_cache_split": bool(
            shared["ttft_p99_us"] > private["ttft_p99_us"]
            and shared["drain_vs"] > private["drain_vs"]
            and shared["link_wait_us"] > private["link_wait_us"]),
    }
    out = {
        "emulate_step_s": EMULATED_STEP_S,
        "saturation_step_s": SATURATION_STEP_S,
        "qps_grid": list(qps_grid),
        "rows": rows,
        "ratios": {"cxl_lo": cxl_ratio_lo, "cxl_hi": cxl_ratio_hi,
                   "rdma_lo": rdma_ratio_lo, "rdma_hi": rdma_ratio_hi},
        "shared_cache_split": {"shared": shared, "private": private},
        "checks": checks,
    }
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    with open(OUT_DIR / "BENCH_load.json", "w") as f:
        json.dump(out, f, indent=2)
    for name, ok in checks.items():
        emit(f"load/check/{name}", 0.0 if ok else 1.0,
             "PASS" if ok else "FAIL")
    if not all(checks.values()):
        raise SystemExit(f"bench_load checks failed: "
                         f"{[k for k, v in checks.items() if not v]}")
    return out


if __name__ == "__main__":
    run(fast="--fast" in sys.argv)
