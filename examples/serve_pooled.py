"""Serve an Engram model with batched requests from a simulated CXL pool,
reproducing the Table 2 comparison (baseline / +Engram DRAM / +Engram CXL).

    PYTHONPATH=src python examples/serve_pooled.py [--requests 8]
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.launch.serve import main as serve_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args()
    return serve_main(["--arch", "deepseek-7b", "--reduced", "--compare",
                       "--requests", str(args.requests),
                       "--max-new", str(args.max_new),
                       "--max-batch", "4", "--max-len", "64"])


if __name__ == "__main__":
    sys.exit(main())
