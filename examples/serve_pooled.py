"""Serve an Engram model with batched requests from a simulated CXL pool,
reproducing the Table 2 comparison (baseline / +Engram DRAM / +Engram CXL).

All pool behaviour — tier latency, the optional LRU hot-row cache, and
prefetch-window stalls — comes from the tiered EngramStore subsystem
(src/repro/pool/store.py); the engine just charges what the store reports.

    PYTHONPATH=src python examples/serve_pooled.py [--requests 8]
    # paper §6 rescue, end-to-end: RDMA backing tier + DRAM hot-row cache
    PYTHONPATH=src python examples/serve_pooled.py --pool RDMA --cache-rows 100000
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.launch.serve import main as serve_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--pool", default=None,
                    choices=["DRAM", "CXL", "RDMA", "RDMA-agg", "HBM"])
    ap.add_argument("--cache-rows", type=int, default=0,
                    help="LRU hot-row cache rows in front of --pool")
    args = ap.parse_args()
    argv = ["--arch", "deepseek-7b", "--reduced",
            "--requests", str(args.requests),
            "--max-new", str(args.max_new),
            "--max-batch", "4", "--max-len", "64"]
    if args.pool:
        argv += ["--pool", args.pool, "--cache-rows", str(args.cache_rows)]
    else:
        if args.cache_rows:
            ap.error("--cache-rows needs --pool (the cache fronts a "
                     "backing tier; compare mode runs fixed variants)")
        argv += ["--compare"]
    return serve_main(argv)


if __name__ == "__main__":
    sys.exit(main())
