"""Serve an Engram model with batched requests from a simulated CXL pool,
reproducing the Table 2 comparison (baseline / +Engram DRAM / +Engram CXL).

All pool behaviour — tier latency, the optional LRU hot-row cache, and
prefetch-window stalls — comes from the tiered EngramStore subsystem
(src/repro/pool/store.py); the engine just charges what the store reports.

    PYTHONPATH=src python examples/serve_pooled.py [--requests 8]
    # paper §6 rescue, end-to-end: RDMA backing tier + DRAM hot-row cache
    PYTHONPATH=src python examples/serve_pooled.py --pool RDMA --cache-rows 100000
    # §3.2 deep lookahead: speculative decoding widens the prefetch window
    PYTHONPATH=src python examples/serve_pooled.py --pool RDMA --speculate
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.launch.serve import main as serve_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=None,
                    help="default 8 (12 with --speculate: enough replays "
                         "of the hot prompt to show the widened window)")
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--pool", default=None,
                    choices=["DRAM", "CXL", "RDMA", "RDMA-agg", "HBM"])
    ap.add_argument("--cache-rows", type=int, default=0,
                    help="LRU hot-row cache rows in front of --pool")
    ap.add_argument("--admission", default="lru",
                    choices=["lru", "tinylfu"],
                    help="cache admission policy (tinylfu = scan-resistant)")
    ap.add_argument("--speculate", action="store_true",
                    help="speculative decoding (n-gram proposer)")
    args = ap.parse_args()
    if args.admission != "lru" and not args.cache_rows:
        ap.error("--admission needs --cache-rows (the policy gates inserts "
                 "into the hot-row cache)")
    requests = args.requests if args.requests is not None \
        else (12 if args.speculate else 8)
    argv = ["--arch", "deepseek-7b", "--reduced",
            "--requests", str(requests),
            "--max-new", str(args.max_new),
            "--max-len", "64"]
    if args.speculate:
        # repeat traffic from a hot prompt: replayed greedy continuations
        # are what the n-gram proposer accepts on (a unique-random
        # workload would honestly show ~0% acceptance), and a narrow
        # batch keeps replays *behind* the first request instead of in
        # cold lockstep beside it
        argv += ["--speculate", "--prompt-pool", "1", "--max-batch", "2"]
    else:
        argv += ["--max-batch", "4"]
    if args.pool:
        argv += ["--pool", args.pool, "--cache-rows", str(args.cache_rows)]
        if args.cache_rows:
            argv += ["--admission", args.admission]
    else:
        if args.cache_rows:
            ap.error("--cache-rows needs --pool (the cache fronts a "
                     "backing tier; compare mode runs fixed variants)")
        argv += ["--compare"]
    return serve_main(argv)


if __name__ == "__main__":
    sys.exit(main())
