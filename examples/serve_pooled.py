"""Serve an Engram model from a simulated CXL pool through the
request-lifecycle `EngramRuntime` API, reproducing the Table 2 comparison
(baseline / +Engram DRAM / +Engram CXL) and streaming tokens per request.

All pool behaviour — tier latency, the optional LRU hot-row cache, and
prefetch-window stalls — comes from the tiered EngramStore subsystem
(src/repro/pool/store.py); the runtime steps the engine one admit+decode
wave at a time and routes every token to its request's handle.

    PYTHONPATH=src python examples/serve_pooled.py [--requests 8]
    # paper §6 rescue, end-to-end: RDMA backing tier + DRAM hot-row cache
    PYTHONPATH=src python examples/serve_pooled.py --pool RDMA --cache-rows 100000
    # §3.2 deep lookahead: speculative decoding widens the prefetch window
    PYTHONPATH=src python examples/serve_pooled.py --pool RDMA --speculate
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.configs.base import SpecConfig
from repro.launch.serve import run_compare, with_store
from repro.launch.train import reduced_config
from repro.serving import EngramRuntime, Workload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=None,
                    help="default 8 (12 with --speculate: enough replays "
                         "of the hot prompt to show the widened window)")
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--pool", default=None,
                    choices=["DRAM", "CXL", "RDMA", "RDMA-agg", "HBM"])
    ap.add_argument("--cache-rows", type=int, default=0,
                    help="LRU hot-row cache rows in front of --pool")
    ap.add_argument("--admission", default="lru",
                    choices=["lru", "tinylfu"],
                    help="cache admission policy (tinylfu = scan-resistant)")
    ap.add_argument("--speculate", action="store_true",
                    help="speculative decoding (n-gram proposer)")
    args = ap.parse_args()
    if args.admission != "lru" and not args.cache_rows:
        ap.error("--admission needs --cache-rows (the policy gates inserts "
                 "into the hot-row cache)")
    if args.cache_rows and not args.pool:
        ap.error("--cache-rows needs --pool (the cache fronts a backing "
                 "tier; compare mode runs fixed variants)")
    if args.speculate and not args.pool:
        ap.error("--speculate needs --pool (compare mode runs the fixed "
                 "Table 2 variants; speculation would change all three)")
    requests = args.requests if args.requests is not None \
        else (12 if args.speculate else 8)

    cfg = reduced_config("deepseek-7b")
    if args.cache_rows:
        cfg = with_store(cfg, cache_rows=args.cache_rows,
                         admission=args.admission)
    spec = SpecConfig(proposer="ngram") if args.speculate else None
    # repeat traffic from a hot prompt under --speculate: replayed greedy
    # continuations are what the n-gram proposer accepts on (unique-random
    # traffic would honestly show ~0% acceptance), and a narrow batch
    # keeps replays *behind* the first request instead of in cold lockstep
    workload = Workload(requests=requests, max_new=args.max_new,
                        prompt_pool=1 if args.speculate else 0)
    max_batch = 2 if args.speculate else 4

    if args.pool is None:
        run_compare(cfg, requests=requests, max_new=args.max_new,
                    max_batch=max_batch, max_len=64)
        return 0

    # single-pool run, driven by hand to show the lifecycle surface:
    # submit -> handles, step -> TokenEvents, per-handle token streams
    rt = EngramRuntime(cfg, pool=args.pool, max_batch=max_batch,
                       max_len=64, spec=spec)
    handles = [rt.submit(list(spec_.prompt), spec_.max_new)
               for spec_ in workload.build(cfg.vocab_size)]
    if handles:
        first = handles[0]
        print(f"request {first.rid} streams:",
              " ".join(str(t) for t in first.stream()))
    stats = rt.drain()                   # finish the rest
    print(f"pool={args.pool}: {stats.generated_tokens} tokens "
          f"from {stats.requests_completed} requests = "
          f"{stats.tokens_per_s:.1f} tok/s "
          f"(stall {stats.stall_s * 1e3:.1f} ms, "
          f"mean TTFT {stats.mean_ttft_s * 1e3:.1f} ms)")
    if args.speculate:
        print(f"speculate: acceptance={stats.acceptance_rate:.3f} "
              f"({stats.accepted_tokens}/{stats.proposed_tokens} drafts)")
    s = rt.store.stats()
    print(f"store[{s.tier}]: {s.segments} segments, "
          f"hit_rate={s.hit_rate:.3f} "
          f"(cache={s.cache_rows} rows @ {s.cache_tier}), "
          f"hidden {s.hidden_waves}/{s.waves} waves")
    if s.spec_waves:
        print(f"spec-prefetch: window={s.spec_window_steps:.2f} decode "
              f"steps (measured), wasted={s.wasted_prefetch_rate:.3f} "
              f"of segments")
    return 0


if __name__ == "__main__":
    sys.exit(main())
