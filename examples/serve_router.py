"""Multi-replica serving demo: a Router fleet multiplexing one Engram pool
through a single shared hot-row cache, with streamed and cancelled
requests — the full request-lifecycle surface on a tiny config.

This doubles as the CI serve-smoke: it exercises submit/step/stream/
cancel/drain, the shared-cache hit path across replicas, and the private-
cache baseline comparison, and fails loudly if any of it regresses.

    PYTHONPATH=src python examples/serve_router.py [--fast]
"""
import argparse
import dataclasses
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.launch.serve import with_store
from repro.launch.train import reduced_config
from repro.serving import Router, Workload


def tiny_cfg():
    cfg = reduced_config("deepseek-7b")
    cfg = dataclasses.replace(cfg, n_layers=3, layer_types=("attn",) * 3,
                              attn_kinds=("global",) * 3,
                              ffn_types=("dense",) * 3,
                              engram=dataclasses.replace(cfg.engram,
                                                         layers=(1,)))
    return with_store(cfg, cache_rows=50_000)


def run_fleet(cfg, workload, *, shared: bool, cancel: int = 0,
              stream_first: bool = False):
    router = Router(cfg, replicas=2, pool="RDMA", policy="round_robin",
                    shared_cache=shared, max_batch=2, max_len=64,
                    prompt_bucket=8)
    handles = [router.submit(list(s.prompt), s.max_new)
               for s in workload.build(cfg.vocab_size)]
    if stream_first and handles:
        toks = list(handles[0].stream())     # steps its replica as needed
        print(f"  streamed request {handles[0].rid}: {toks}")
        assert toks == handles[0].tokens and handles[0].finished
    if cancel:
        # cancel the last `cancel` still-pending requests mid-flight
        pending = [h for h in handles if not h.finished]
        for h in pending[-cancel:]:
            assert h.cancel(), f"cancel({h.rid}) failed"
    router.drain()
    for h in handles:
        assert h.finished or h.cancelled, (h.rid, h.status)
    return router, handles


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--requests", type=int, default=None)
    args = ap.parse_args()
    n = args.requests if args.requests is not None \
        else (6 if args.fast else 10)
    cfg = tiny_cfg()
    # shared-prompt traffic (3 hot prompts): the regime where one cache
    # across replicas pays — replica B hits rows replica A fetched
    wl = Workload(requests=n, max_new=4, prompt_pool=3)

    print("router x2 (shared cache), streamed + cancelled requests:")
    router, handles = run_fleet(cfg, wl, shared=True, stream_first=True,
                                cancel=1)
    rs = router.stats()
    cancelled = [h.rid for h in handles if h.cancelled]
    print(f"  fleet: {rs.aggregate.generated_tokens} tokens, "
          f"{rs.aggregate.requests_completed} completed, "
          f"cancelled {cancelled}")
    for name, st in rs.per_replica.items():
        print(f"  {name}: {st.generated_tokens} tokens, "
              f"{st.prefills} prefills")
    shared_hit = rs.cache.hit_rate
    print(f"  shared-cache hit_rate={shared_hit:.3f} "
          f"({rs.cache.hits}/{rs.cache.hits + rs.cache.misses})")
    assert rs.aggregate.requests_cancelled == len(cancelled) == 1

    router2, _ = run_fleet(cfg, wl, shared=False)
    stores = router2.store_stats()
    hits = sum(s.hits for s in stores.values())
    total = sum(s.hits + s.misses for s in stores.values())
    private_hit = hits / max(total, 1)
    print(f"router x2 (private caches) hit_rate={private_hit:.3f}")
    assert shared_hit > private_hit, (shared_hit, private_hit)
    print(f"shared cache beats private: "
          f"{shared_hit:.3f} > {private_hit:.3f}  OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
