"""End-to-end driver: train an Engram LM on the synthetic n-gram corpus,
with checkpointing and an injected mid-run failure + automatic restart.

    PYTHONPATH=src python examples/train_engram_lm.py [--steps 200] \
        [--inject-failure] [--ckpt-dir /tmp/engram_ckpt]

The dataset embeds deterministic bigram transitions (55% of tokens); the
Engram tables can memorize exactly these, which is the paper's motivating
division of labour (lookup vs compute). Scale --d-model/--layers up on
real hardware; defaults fit a CPU smoke run.
"""
import argparse
import os
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import dataclasses

from repro.configs.base import EngramConfig, ModelConfig
from repro.data import DataConfig
from repro.models.transformer import RunFlags
from repro.train import AdamWConfig, TrainConfig, train_with_restarts


def build_cfg(args) -> ModelConfig:
    return ModelConfig(
        name="engram-lm-example", family="dense",
        n_layers=args.layers, d_model=args.d_model,
        vocab_size=args.vocab, n_heads=4, n_kv_heads=4,
        head_dim=args.d_model // 4, d_ff=args.d_model * 3,
        engram=EngramConfig(orders=(2, 3), n_heads=4, emb_dim=args.d_model,
                            table_vocab=8192,
                            layers=(1, max(2, args.layers // 2)),
                            strategy="local"),
        dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--d-model", type=int, default=64)
    ap.add_argument("--vocab", type=int, default=2048)
    ap.add_argument("--ckpt-dir", default="/tmp/engram_lm_ckpt")
    ap.add_argument("--inject-failure", action="store_true",
                    help="crash at 60%% of training and auto-restart")
    args = ap.parse_args()

    cfg = build_cfg(args)
    print(f"params: {cfg.param_count()/1e6:.1f}M "
          f"(engram tables {cfg.engram.table_params()/1e6:.1f}M)")
    if args.inject_failure:
        os.environ["REPRO_FAIL_AT_STEP"] = str(int(args.steps * 0.6))
        print(f"will inject a failure at step {int(args.steps * 0.6)}")

    tc = TrainConfig(steps=args.steps, log_every=max(args.steps // 10, 1),
                     ckpt_every=max(args.steps // 4, 1))
    dc = DataConfig(vocab_size=cfg.vocab_size, batch=args.batch,
                    seq_len=args.seq, ngram_p=0.55)
    res = train_with_restarts(
        cfg, tc, dc, ckpt_dir=args.ckpt_dir,
        oc=AdamWConfig(lr=2e-3, warmup_steps=max(args.steps // 20, 1),
                       decay_steps=args.steps))
    print(f"\nloss {res.losses[0]:.3f} -> {res.losses[-1]:.3f} "
          f"over {args.steps} steps, restarts={res.restarts}")
    import math
    # a model that memorized the bigram table approaches
    # H = (1-p)*H(zipf) ; report the deterministic-fraction headroom
    print("engram headroom: 55% of transitions are table lookups "
          "(deterministic) — loss below ~0.45*H(zipf) means the tables "
          "are doing their job")


if __name__ == "__main__":
    main()
