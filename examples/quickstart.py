"""Quickstart: Engram conditional memory + CXL-pool feasibility in 2 minutes.

    PYTHONPATH=src python examples/quickstart.py

Builds a small Engram-augmented LM, shows the three pieces of the paper:
(1) hash-only retrieval indices (prefetchable), (2) pooled lookup + gated
fusion in a forward pass, (3) the §3.2 feasibility check for DRAM/CXL/RDMA.
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ENGRAM_27B, EngramConfig, get_config
from repro.configs import deepseek_7b
from repro.core.hashing import engram_indices
from repro.core.engram import engram_lookup
from repro.data import DataConfig, TokenPipeline
from repro.models.model import build_loss_fn, init_params
from repro.models.transformer import RunFlags
from repro.pool import check_all_tiers, latency_sweep, paper_case_study


def main():
    cfg = deepseek_7b.reduced()
    e = cfg.engram
    print(f"model: {cfg.name}  layers={cfg.n_layers} d_model={cfg.d_model}")
    print(f"engram: orders={e.orders} heads={e.n_heads} "
          f"tables={e.n_tables} x {e.table_vocab} rows, "
          f"{e.bytes_per_token_layer} B/token/layer at layers "
          f"{cfg.engram_layers()}")

    # 1. indices depend only on token IDs -> prefetchable at step start
    toks = jnp.asarray([[11, 22, 33, 44, 55]], jnp.int32)
    idx = engram_indices(e, toks)
    print(f"\n[1] engram indices (B,S,T) = {idx.shape}; "
          f"first token -> rows {np.asarray(idx)[0, 0][:4]}...")

    # 2. retrieval + a full train step through the gated fusion
    params = init_params(cfg, 0)
    rows = engram_lookup(cfg, params["engram"], toks)
    print(f"[2] retrieved rows {rows.shape} "
          f"({rows.dtype}, {rows.size * rows.dtype.itemsize} B)")
    dc = DataConfig(vocab_size=cfg.vocab_size, batch=2, seq_len=32)
    batch = {k: jnp.asarray(v) for k, v in TokenPipeline(dc).batch_at(0).items()}
    loss = build_loss_fn(cfg, RunFlags())(params, batch)
    print(f"    one forward+loss through 2 Engram layers: loss={float(loss):.3f}")

    # 3. the paper's feasibility model (Table 1 case study)
    print("\n[3] §3.2 feasibility @ Qwen3-32B-like point "
          "(70k tok/s, 3.6 ms step, 64 layers):")
    for tier, f in check_all_tiers(EngramConfig(**ENGRAM_27B),
                                   paper_case_study()).items():
        print(f"    {tier:5s} window={f.prefetch_window_s*1e6:6.1f}us "
              f"latency={f.retrieval_latency_s*1e6:8.1f}us  "
              f"{'OK — retrieval hides' if f.ok else 'STALLS'}")

    print("\n[4] Fig 3-style latency sweep (Engram-27B, us):")
    sweep = latency_sweep(EngramConfig(**ENGRAM_27B),
                          batch_sizes=(1, 64, 256, 1024))
    print("    batch " + "".join(f"{t:>10s}" for t in sweep))
    for i, b in enumerate((1, 64, 256, 1024)):
        print(f"    {b:5d} " + "".join(f"{sweep[t][i][1]:10.1f}"
                                       for t in sweep))


if __name__ == "__main__":
    main()
