"""Lower + compile one (arch x shape) cell on the 512-chip multi-pod mesh
and print its roofline terms — the smallest end-to-end tour of the
distribution stack.

    PYTHONPATH=src python examples/multipod_dryrun.py \
        [--arch gemma3-1b] [--shape decode_32k]

(Must be a fresh process: the 512 fake devices are configured before jax
initializes. Takes a few minutes of XLA compile time on CPU.)
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--single-pod", action="store_true")
    args = ap.parse_args()

    # import order matters: dryrun sets XLA_FLAGS before jax loads
    from repro.launch.dryrun import lower_cell
    from repro.roofline.analysis import roofline

    rec = lower_cell(args.arch, args.shape, multi_pod=not args.single_pod)
    if not rec["ok"]:
        print("FAILED:", rec["error"])
        return 1
    n = rec["n_devices"]
    r = roofline(rec["cost"]["flops"], rec["cost"]["bytes_accessed"],
                 rec["collectives"]["total_wire_bytes_per_device"])
    print(f"{rec['arch']} x {rec['shape']} on {rec['mesh']} "
          f"({n} devices): compiled OK in {rec['compile_s']}s")
    print(f"  params {rec['params']/1e9:.1f}B "
          f"(active {rec['active_params']/1e9:.1f}B)")
    print(f"  per-device arg bytes {rec['memory']['argument_bytes']/2**30:.2f} GiB")
    print(f"  roofline: compute {r.compute_s*1e3:.2f} ms | "
          f"memory {r.memory_s*1e3:.2f} ms | "
          f"collective {r.collective_s*1e3:.2f} ms -> {r.bound}-bound")
    return 0


if __name__ == "__main__":
    sys.exit(main())
